"""LK: lock discipline (DESIGN.md §11/§13 serve-spine threading).

The checked file *declares* its own locking contract as module
constants (see ``src/repro/serve/scheduler.py``)::

    _GUARDED_BY = {
        "_lock": ("queue", "_seq", ...),
        "_pump_lock": ("_int2ext", "_ext2int", ...),
    }
    _LOCK_ORDER = ("_pump_lock", "_lock")   # outer → inner

Codes:

LK201  an attribute listed in ``_GUARDED_BY`` is accessed in a method
       of the declaring file's classes without its lock held.  Held
       locks are tracked through ``with self.<lock>:`` blocks plus an
       intra-class fixpoint: a private method whose *every* in-class
       call site holds lock L is analyzed with L held on entry.
LK202  lock acquired while holding another in the opposite order from
       ``_LOCK_ORDER`` — the classic ABBA deadlock shape.

``__init__`` is exempt (single-threaded construction); nested
functions/lambdas are analyzed with no locks held (they may run on
another thread later).  Single-threaded exceptions (recovery replay)
carry ``# lint-ok[LK201]: <reason>`` block suppressions.
"""

from __future__ import annotations

import ast
from typing import Dict, FrozenSet, Iterable, List, Optional, Set, Tuple

from tools.repro_lint.driver import Finding
from tools.repro_lint.project import Project, SourceFile
from tools.repro_lint.registry import register


def _module_decls(sf: SourceFile):
    guarded: Optional[Dict[str, Tuple[str, ...]]] = None
    order: Optional[Tuple[str, ...]] = None
    for node in sf.tree.body:
        if not isinstance(node, ast.Assign):
            continue
        for t in node.targets:
            if not isinstance(t, ast.Name):
                continue
            if t.id == "_GUARDED_BY" and isinstance(node.value, ast.Dict):
                guarded = {}
                for k, v in zip(node.value.keys, node.value.values):
                    if isinstance(k, ast.Constant) and \
                            isinstance(v, (ast.Tuple, ast.List)):
                        guarded[k.value] = tuple(
                            e.value for e in v.elts
                            if isinstance(e, ast.Constant))
            elif t.id == "_LOCK_ORDER" and isinstance(
                    node.value, (ast.Tuple, ast.List)):
                order = tuple(e.value for e in node.value.elts
                              if isinstance(e, ast.Constant))
    return guarded, order


def _self_attr(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


class _MethodSim:
    """Walk one method body tracking held locks."""

    def __init__(self, guarded: Dict[str, Tuple[str, ...]],
                 order: Optional[Tuple[str, ...]], path: str):
        self.guard_of: Dict[str, str] = {
            attr: lock for lock, attrs in guarded.items()
            for attr in attrs}
        self.locks = set(guarded)
        self.order = order or ()
        self.path = path
        self.findings: List[Finding] = []
        # held sets observed at each intra-class call: name -> list
        self.call_sites: Dict[str, List[FrozenSet[str]]] = {}

    def run(self, fn: ast.FunctionDef, entry: FrozenSet[str]) -> None:
        self._walk(fn.body, set(entry))

    def _walk(self, stmts: List[ast.stmt], held: Set[str]) -> None:
        for stmt in stmts:
            self._stmt(stmt, held)

    def _stmt(self, stmt: ast.stmt, held: Set[str]) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self._walk(stmt.body, set())      # deferred execution
            return
        if isinstance(stmt, ast.With):
            acquired: List[str] = []
            for item in stmt.items:
                lock = _self_attr(item.context_expr)
                if lock in self.locks:
                    if lock not in held:
                        self._check_order(lock, held,
                                          item.context_expr.lineno)
                        acquired.append(lock)
                        held.add(lock)
                else:
                    self._exprs(item.context_expr, held)
            self._walk(stmt.body, held)
            for lock in acquired:
                held.discard(lock)
            return
        if isinstance(stmt, ast.If):
            self._exprs(stmt.test, held)
            self._walk(stmt.body, set(held))
            self._walk(stmt.orelse, set(held))
            return
        if isinstance(stmt, (ast.For, ast.While)):
            self._exprs(getattr(stmt, "iter", None) or stmt.test, held)
            self._walk(stmt.body, set(held))
            self._walk(stmt.orelse, set(held))
            return
        if isinstance(stmt, ast.Try):
            self._walk(stmt.body, set(held))
            for h in stmt.handlers:
                self._walk(h.body, set(held))
            self._walk(stmt.orelse, set(held))
            self._walk(stmt.finalbody, set(held))
            return
        self._exprs(stmt, held)

    def _check_order(self, lock: str, held: Set[str],
                     lineno: int) -> None:
        if lock not in self.order:
            return
        pos = self.order.index(lock)
        for h in held:
            if h in self.order and self.order.index(h) > pos:
                self.findings.append(Finding(
                    code="LK202", path=self.path, line=lineno,
                    message=f"acquiring `{lock}` while holding `{h}` "
                            "inverts the declared _LOCK_ORDER "
                            f"{self.order} — ABBA deadlock risk"))

    def _exprs(self, node: Optional[ast.AST], held: Set[str]) -> None:
        if node is None:
            return
        if isinstance(node, (ast.Lambda, ast.FunctionDef,
                             ast.AsyncFunctionDef)):
            return          # deferred execution: no locks assumed held
        if isinstance(node, ast.Call):
            attr = _self_attr(node.func)
            if attr is not None:
                self.call_sites.setdefault(attr, []).append(
                    frozenset(held))
        attr = _self_attr(node)
        if attr is not None:
            lock = self.guard_of.get(attr)
            if lock is not None and lock not in held:
                self.findings.append(Finding(
                    code="LK201", path=self.path, line=node.lineno,
                    message=f"`self.{attr}` accessed without holding "
                            f"`{lock}` (declared in _GUARDED_BY)"))
            return          # don't descend into `self`
        for sub in ast.iter_child_nodes(node):
            self._exprs(sub, held)


@register("lock-discipline")
def check_lock_discipline(project: Project) -> Iterable[Finding]:
    findings: List[Finding] = []
    for sf in project.files.values():
        guarded, order = _module_decls(sf)
        if not guarded:
            continue
        for cls in sf.tree.body:
            if not isinstance(cls, ast.ClassDef):
                continue
            methods = {n.name: n for n in cls.body
                       if isinstance(n, ast.FunctionDef)}
            if not _uses_locks(methods.values(), set(guarded)):
                continue
            findings.extend(
                _check_class(sf, methods, guarded, order))
    return findings


def _uses_locks(methods, locks: Set[str]) -> bool:
    for m in methods:
        for node in ast.walk(m):
            if _self_attr(node) in locks:
                return True
    return False


def _check_class(sf: SourceFile, methods: Dict[str, ast.FunctionDef],
                 guarded: Dict[str, Tuple[str, ...]],
                 order: Optional[Tuple[str, ...]]) -> List[Finding]:
    all_locks = frozenset(guarded)
    # entry-held fixpoint: private methods start optimistic (all locks),
    # public methods are externally callable → nothing held on entry
    entry: Dict[str, FrozenSet[str]] = {}
    for name in methods:
        private = name.startswith("_") and not name.startswith("__")
        entry[name] = all_locks if private else frozenset()
    for _ in range(len(methods) + 2):
        sites: Dict[str, List[FrozenSet[str]]] = {}
        for name, node in methods.items():
            if name == "__init__":
                continue
            sim = _MethodSim(guarded, order, sf.path)
            sim.run(node, entry[name])
            for callee, helds in sim.call_sites.items():
                sites.setdefault(callee, []).extend(helds)
        changed = False
        for name in methods:
            if not (name.startswith("_")
                    and not name.startswith("__")):
                continue
            observed = sites.get(name)
            new = frozenset.intersection(*observed) if observed \
                else frozenset()
            if new != entry[name]:
                entry[name] = new
                changed = True
        if not changed:
            break
    findings: List[Finding] = []
    for name, node in methods.items():
        if name == "__init__":
            continue
        sim = _MethodSim(guarded, order, sf.path)
        sim.run(node, entry[name])
        findings.extend(sim.findings)
    return findings
