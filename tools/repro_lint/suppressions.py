"""Suppression-comment parsing.

Two spellings, both requiring a human-readable reason:

``# sync-ok: <reason>``
    Suppresses host-sync findings (HS*) on the annotated statement.

``# lint-ok: <reason>`` / ``# lint-ok[CODE]: <reason>``
    Suppresses any rule (or one specific code) on the statement.

Placement:

- trailing on a line: covers every finding reported on that line and,
  when the line opens a multi-line statement, the whole statement;
- on its own line: covers the next non-comment statement;
- trailing on a ``def``/``class`` line with an explicit ``[CODE]``
  tag: covers the entire body (block scope) for that code.

A suppression with a missing or empty reason is itself a fatal
finding (SUP001) — the convention exists to force the *why* into the
source, not to provide an escape hatch.
"""

from __future__ import annotations

import io
import re
import tokenize
from dataclasses import dataclass, field
from typing import List, Optional, Set

_PATTERN = re.compile(
    r"#\s*(?P<kind>sync-ok|lint-ok)"
    r"(?:\[(?P<code>[A-Za-z0-9_,\s]+)\])?"
    r"\s*(?::\s*(?P<reason>.*))?$")


@dataclass
class Suppression:
    kind: str                   # "sync-ok" | "lint-ok"
    line: int                   # line the comment sits on
    codes: Optional[Set[str]]   # None = kind's whole family
    reason: str
    standalone: bool            # comment-only line (covers next stmt)
    on_def_line: bool = False   # block scope when code-tagged
    used: bool = field(default=False, compare=False)

    def matches(self, code: str) -> bool:
        if self.codes is not None:
            return code in self.codes or any(
                code.startswith(c) for c in self.codes)
        if self.kind == "sync-ok":
            return code.startswith("HS")
        return True              # bare lint-ok: any rule


def parse_suppressions(source: str) -> List[Suppression]:
    out: List[Suppression] = []
    lines = source.splitlines()
    try:
        tokens = list(tokenize.generate_tokens(
            io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError):
        return out
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _PATTERN.search(tok.string)
        if not m:
            continue
        line_no = tok.start[0]
        text_before = lines[line_no - 1][:tok.start[1]]
        codes = None
        if m.group("code"):
            codes = {c.strip().upper()
                     for c in m.group("code").split(",") if c.strip()}
        out.append(Suppression(
            kind=m.group("kind"),
            line=line_no,
            codes=codes,
            reason=(m.group("reason") or "").strip(),
            standalone=not text_before.strip(),
            on_def_line=bool(
                re.match(r"\s*(async\s+def|def|class)\b", text_before)),
        ))
    return out
