"""Parsed-source model shared by every rule.

A `Project` holds one `SourceFile` per analyzed module (AST + raw
lines + the suppression table) plus lazily-built cross-file indices:
every function/method definition keyed by name, and a name-based
call-graph approximation rules use for reachability questions
("is this function on the serve hot path?").

The call graph is deliberately an over-approximation: a call ``x.f()``
edges to *every* definition named ``f`` (filtered for the
`VectorBackend` protocol method names, which only resolve into backend
implementation classes — see `CallGraph`).  Over-approximation errs
toward flagging, and a human answers with an explicit suppression
comment carrying a reason — never silently.
"""

from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Set, Tuple

from tools.repro_lint.suppressions import Suppression, parse_suppressions

#: method names owned by the `VectorBackend` protocol (plus the
#: `SearchHandle` pair).  Calls to these names only resolve into
#: classes that implement the protocol surface — otherwise every
#: baseline's host-native `search` would be pulled onto the hot path.
PROTOCOL_METHOD_NAMES = frozenset({
    "search", "dispatch_search", "collect", "is_ready",
    "insert_batch", "delete_batch", "maintain", "begin_maintain",
    "poll_maintain", "stats", "memory_bytes", "heat_total",
    "reset_heat", "initial_ids", "trace_counts", "sync", "save",
})

#: ubiquitous builtin-collection method names that would otherwise
#: create edges to any same-named def in the repo
_STOP_CALL_NAMES = frozenset({
    "append", "extend", "add", "discard", "remove", "clear", "pop",
    "get", "items", "keys", "values", "update", "join", "split",
    "strip", "sort", "copy", "format", "encode", "decode", "read",
    "write", "close", "flush", "sum", "max", "min", "mean", "any",
    "all", "tolist", "item", "astype", "reshape", "set", "wait",
})


@dataclass
class FunctionInfo:
    """One function/method definition and its outgoing call names."""

    qualname: str               # "module::Class.method" or "module::func"
    name: str
    cls: Optional[str]
    module: str                 # project-relative path of the file
    node: ast.AST               # FunctionDef / AsyncFunctionDef
    is_property: bool = False
    calls: Set[str] = field(default_factory=set)
    attr_loads: Set[str] = field(default_factory=set)


class SourceFile:
    """One parsed module: text, AST, and its suppression table."""

    def __init__(self, path: str, text: str):
        self.path = path
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=path)
        self.suppressions: List[Suppression] = parse_suppressions(text)

    def iter_functions(self) -> Iterable[FunctionInfo]:
        for node in self.tree.body:
            yield from _functions_in(node, self.path, cls=None)

    def iter_classes(self) -> Iterable[ast.ClassDef]:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.ClassDef):
                yield node


def _functions_in(node: ast.AST, module: str,
                  cls: Optional[str]) -> Iterable[FunctionInfo]:
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
        qual = f"{module}::{cls + '.' if cls else ''}{node.name}"
        info = FunctionInfo(
            qualname=qual, name=node.name, cls=cls, module=module,
            node=node, is_property=_is_property(node))
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                callee = _call_name(sub.func)
                if callee:
                    info.calls.add(callee)
            elif isinstance(sub, ast.Attribute) and isinstance(
                    sub.ctx, ast.Load):
                info.attr_loads.add(sub.attr)
        yield info
        # nested defs are visited for completeness but keep the same
        # class context (closure helpers, jit bodies)
        for sub in node.body:
            yield from _functions_in(sub, module, cls)
    elif isinstance(node, ast.ClassDef):
        for sub in node.body:
            yield from _functions_in(sub, module, node.name)


def _is_property(node: ast.AST) -> bool:
    for dec in getattr(node, "decorator_list", []):
        if isinstance(dec, ast.Name) and dec.id == "property":
            return True
        if isinstance(dec, ast.Attribute) and dec.attr in (
                "getter", "setter", "cached_property"):
            return True
    return False


def _call_name(func: ast.AST) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


class CallGraph:
    """Name-matched call graph over every definition in the project."""

    def __init__(self, functions: List[FunctionInfo]):
        self.functions = functions
        self.by_name: Dict[str, List[FunctionInfo]] = {}
        self._backend_classes: Set[Tuple[str, str]] = set()
        cls_methods: Dict[Tuple[str, str], Set[str]] = {}
        for f in functions:
            self.by_name.setdefault(f.name, []).append(f)
            if f.cls is not None:
                cls_methods.setdefault((f.module, f.cls), set()).add(f.name)
        for key, methods in cls_methods.items():
            # protocol-name resolution targets: backend implementations
            # and search handles (classes defining dispatch_search or
            # collect); the `VectorBackend` Protocol class itself and
            # host-native baselines never serve
            if "dispatch_search" in methods or "collect" in methods:
                self._backend_classes.add(key)

    def targets(self, name: str) -> List[FunctionInfo]:
        cands = self.by_name.get(name, [])
        if name in PROTOCOL_METHOD_NAMES:
            return [f for f in cands if f.cls is not None
                    and (f.module, f.cls) in self._backend_classes]
        if name in _STOP_CALL_NAMES:
            return []
        return cands

    def reachable(self, roots: Iterable[FunctionInfo]) -> Set[str]:
        """Qualnames reachable from `roots` via call edges; property
        definitions are reached through plain attribute loads too."""
        seen: Set[str] = set()
        work = list(roots)
        prop_names = {f.name for f in self.functions if f.is_property}
        while work:
            f = work.pop()
            if f.qualname in seen:
                continue
            seen.add(f.qualname)
            names = set(f.calls)
            names |= {a for a in f.attr_loads if a in prop_names}
            for callee in names:
                for tgt in self.targets(callee):
                    if tgt.qualname not in seen:
                        work.append(tgt)
        return seen


class Project:
    """All analyzed sources plus shared indices."""

    def __init__(self, files: Dict[str, SourceFile],
                 errors: Optional[List[Tuple[str, str]]] = None):
        self.files = files
        self.errors = errors or []     # (path, message) parse failures
        self._functions: Optional[List[FunctionInfo]] = None
        self._callgraph: Optional[CallGraph] = None

    @classmethod
    def from_paths(cls, paths: Iterable[str],
                   root: str = ".") -> "Project":
        files: Dict[str, SourceFile] = {}
        errors: List[Tuple[str, str]] = []
        for path in _collect_py(paths, root):
            rel = os.path.relpath(path, root)
            try:
                with open(path, encoding="utf-8") as f:
                    files[rel] = SourceFile(rel, f.read())
            except (SyntaxError, UnicodeDecodeError) as e:
                errors.append((rel, str(e)))
        return cls(files, errors)

    @classmethod
    def from_sources(cls, sources: Dict[str, str]) -> "Project":
        """Build from in-memory {path: source} — the test fixture hook."""
        files: Dict[str, SourceFile] = {}
        errors: List[Tuple[str, str]] = []
        for path, text in sources.items():
            try:
                files[path] = SourceFile(path, text)
            except SyntaxError as e:
                errors.append((path, str(e)))
        return cls(files, errors)

    @property
    def functions(self) -> List[FunctionInfo]:
        if self._functions is None:
            self._functions = [f for sf in self.files.values()
                               for f in sf.iter_functions()]
        return self._functions

    @property
    def callgraph(self) -> CallGraph:
        if self._callgraph is None:
            self._callgraph = CallGraph(self.functions)
        return self._callgraph

    def file(self, path: str) -> SourceFile:
        return self.files[path]


def _collect_py(paths: Iterable[str], root: str) -> List[str]:
    out: List[str] = []
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(full) and full.endswith(".py"):
            out.append(full)
            continue
        for dirpath, dirnames, filenames in os.walk(full):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    out.append(os.path.join(dirpath, fn))
    return sorted(out)
