"""CLI: ``python -m tools.repro_lint src tests benchmarks``.

Exit 0 when the tree lints clean (every suppression reasoned), 1 on
any finding, 2 on usage errors.  ``--json PATH`` writes the findings
report consumed by the CI artifact upload.
"""

from __future__ import annotations

import argparse
import sys

from tools.repro_lint.driver import lint_paths
from tools.repro_lint.registry import rule_names


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="repro_lint",
        description="invariant-enforcing static analysis for this repo")
    parser.add_argument("paths", nargs="+",
                        help="files or directories to lint")
    parser.add_argument("--rules", default=None,
                        help="comma-separated rule subset "
                             f"(default: all = {','.join(rule_names())})")
    parser.add_argument("--json", default=None, metavar="PATH",
                        help="also write a JSON findings report")
    parser.add_argument("--root", default=".",
                        help="project root paths are relative to")
    args = parser.parse_args(argv)

    selected = None
    if args.rules:
        selected = [r.strip() for r in args.rules.split(",") if r.strip()]
    try:
        report = lint_paths(args.paths, root=args.root, rules=selected)
    except KeyError as e:
        print(f"repro_lint: {e}", file=sys.stderr)
        return 2

    print(report.render())
    if args.json:
        with open(args.json, "w", encoding="utf-8") as f:
            f.write(report.to_json())
    return 1 if report.failed else 0


if __name__ == "__main__":
    sys.exit(main())
