"""Pluggable rule registry.

A rule is a callable ``(Project) -> Iterable[Finding]`` registered
under a short family name.  The driver runs every registered rule (or
an explicit subset via ``--rules``) and folds the findings through the
suppression tables.
"""

from __future__ import annotations

import importlib
from typing import Callable, Dict, Iterable, List

RULES: Dict[str, Callable] = {}


def register(name: str) -> Callable:
    """Class/function decorator adding a rule under ``name``."""

    def deco(fn: Callable) -> Callable:
        if name in RULES:
            raise ValueError(f"duplicate rule name: {name}")
        RULES[name] = fn
        return fn

    return deco


def load_builtin_rules() -> None:
    """Import the rule modules for their registration side effects."""
    for mod in ("host_sync", "jit_discipline", "lock_discipline",
                "protocol"):
        importlib.import_module(f"tools.repro_lint.rules.{mod}")


def rule_names(selected: Iterable[str] | None = None) -> List[str]:
    load_builtin_rules()
    if selected is None:
        return sorted(RULES)
    unknown = [s for s in selected if s not in RULES]
    if unknown:
        raise KeyError(
            f"unknown rule(s) {unknown}; available: {sorted(RULES)}")
    return list(selected)
