"""Training driver: a small LM for a few hundred steps with the full
substrate — sharded-ready train step, AdamW, cosine schedule, atomic
checkpoints, and a mid-run injected failure that the restart policy
recovers from (the fault-tolerance path the cluster deployment relies on).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import sys

sys.path.insert(0, "src")

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.synth import token_pipeline
from repro.ft import FailureInjector, RestartPolicy, run_with_restarts
from repro.launch import steps as step_lib
from repro.models import transformer as T
from repro.optim import adamw_init


def main(num_steps=200, arch="musicgen-large", ckpt_dir="/tmp/train_lm_ck"):
    cfg = configs.get_config(arch, "smoke")
    train_step = jax.jit(step_lib.make_train_step(
        cfg, peak_lr=3e-3, warmup=20, total=num_steps))

    def init_state():
        params = T.init_params(cfg, jax.random.key(0))
        return {"params": params, "opt": adamw_init(params)}

    losses = []

    def step_fn(state, step):
        tokens, labels = next(token_pipeline(
            8, 32, cfg.vocab_size, seed=1, start_step=step))
        params, opt, metrics = train_step(
            state["params"], state["opt"],
            {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)})
        if step % 20 == 0 or step == num_steps - 1:
            print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
        losses.append(float(metrics["loss"]))
        return {"params": params, "opt": opt}

    t0 = time.monotonic()
    out = run_with_restarts(
        policy=RestartPolicy(ckpt_dir=ckpt_dir, ckpt_every=50,
                             max_restarts=3),
        init_state=init_state, step_fn=step_fn, num_steps=num_steps,
        injector=FailureInjector(fail_at=[num_steps // 2]),
        meta_fn=lambda step: {"data_cursor": step})
    dt = time.monotonic() - t0

    print(f"\ndone in {dt:.1f}s; survived {out['restarts']} injected "
          f"failure(s), resumed from steps {out['resumed_from']}")
    first, last = losses[0], sum(losses[-10:]) / 10
    print(f"loss: {first:.4f} -> {last:.4f} "
          f"({'LEARNING' if last < first * 0.9 else 'check config'})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="musicgen-large")
    args = ap.parse_args()
    main(num_steps=args.steps, arch=args.arch)
