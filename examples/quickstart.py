"""Quickstart: build an LSM-VEC index, update it, search it.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import DISK, HNSWConfig, LSMVecIndex, SearchParams  # noqa: F401
from repro.core.index import brute_force_knn, recall_at_k
from repro.data.synth import make_clustered_vectors


def main():
    dim, n = 64, 2048
    data = make_clustered_vectors(n, dim=dim, seed=0)
    cfg = HNSWConfig(cap=4096, dim=dim, M=12, M_up=6, num_upper=2,
                     ef_search=48, ef_construction=48, k=10,
                     rho=0.8, eps=0.1, use_filter=True)

    print(f"building LSM-VEC over {n} x {dim} vectors ...")
    idx = LSMVecIndex.build(cfg, data)

    queries = make_clustered_vectors(32, dim=dim, seed=7)
    res = idx.search(queries, k=10)           # typed SearchResult
    # knobs ride a typed SearchParams instead of kwargs, e.g.
    #   idx.search(queries, k=10, params=SearchParams(rho=0.7))
    ids = res.ids
    truth = brute_force_knn(jnp.asarray(data), jnp.asarray(queries), 10)
    print(f"recall 10@10 = {recall_at_k(ids, truth):.3f}")
    print(f"I/O stats: {int(idx.io_stats.n_adj)} adjacency reads, "
          f"{int(idx.io_stats.n_vec)} vector fetches, "
          f"{int(idx.io_stats.n_filtered)} skipped by sampling")
    print(f"modeled search cost (paper disk constants): "
          f"{idx.io_cost(DISK) * 1e3 / len(queries):.2f} ms/query")

    # dynamic updates: insert a new cluster, delete some old points
    new_vecs = make_clustered_vectors(16, dim=dim, seed=99) + 30.0
    new = idx.insert_batch(new_vecs)          # typed UpdateResult
    found = idx.search(new_vecs, k=1).ids
    print(f"inserted {new.n_applied}; self-recall of new vectors: "
          f"{(found[:, 0] == np.asarray(new.ids)).mean():.2f}")

    idx.delete_batch(ids[0][:3].tolist())
    ids2 = idx.search(queries[:1], k=10).ids
    assert not set(ids[0][:3]) & set(ids2[0]), "deleted ids must not return"
    print("deletes verified (tombstoned + relinked).")

    print(f"memory-resident footprint: {idx.memory_bytes()/1e6:.2f} MB "
          f"(vectors on 'disk': {idx.state.vectors.nbytes/1e6:.1f} MB)")

    # maintenance: every op goes through the uniform maintain() entry
    # (connectivity-aware reordering here, paper §3.4) and returns one
    # typed MaintenanceReport
    rep = idx.maintain("reorder", window=8, lam=1.0)
    assert rep.applied and rep.perm is not None
    ids3 = idx.search(queries, k=10).ids
    gt3 = brute_force_knn(idx.state.vectors[:idx.state.count],
                          jnp.asarray(queries), 10)
    print(f"post-reorder recall = {recall_at_k(ids3, gt3):.3f}")


if __name__ == "__main__":
    main()
