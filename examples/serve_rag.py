"""End-to-end serving driver: retrieval-augmented generation over the
LSM-VEC online serving engine.

The paper's motivating deployment (§1): a vector database serving ANN
queries for RAG.  This driver wires the full path through `repro.serve`
(DESIGN.md §8) — requests are submitted one at a time, exactly like
independent clients would, and the engine owns batching:

  1. a small LM (the qwen3-family smoke config) embeds documents by
     mean-pooling its final hidden states;
  2. documents live in an LSM-VEC index behind a `ServeEngine`
     (micro-batched queries/inserts/deletes, snapshot-cached reads,
     threshold-driven compaction);
  3. each request: embed query -> submit to the engine -> retrieved doc
     tokens are prepended -> prefill + greedy decode continues the
     sequence.

    PYTHONPATH=src python examples/serve_rag.py
"""

import sys

sys.path.insert(0, "src")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import DISK, HNSWConfig, LSMVecIndex
from repro.models import transformer as T
from repro.serve import MaintenancePolicy, ServeConfig, ServeEngine


def embed(params, cfg, tokens):
    """Mean-pooled final hidden state as the document/query embedding."""
    x = params["embed"][tokens].astype(cfg.act_dtype)
    positions = jnp.arange(x.shape[1])[None, :]
    h, _ = T._backbone(params, cfg, x, positions, remat=False)
    return np.asarray(jnp.mean(h, axis=1), np.float32)


def embed_fallback(params, cfg, tokens):
    """Mean-pooled token embeddings only — used when the transformer
    backbone cannot run (jax API drift on the model stack is a known,
    ROADMAP-tracked issue); keeps the serving path demonstrable."""
    x = params["embed"][tokens].astype(jnp.float32)
    return np.asarray(jnp.mean(x, axis=1), np.float32)


def main(n_docs=512, doc_len=24, n_requests=8, gen_len=12):
    cfg = configs.get_config("qwen3-8b", "smoke")
    model = T.Model(cfg)
    params = model.init(jax.random.key(0))

    rng = np.random.default_rng(0)
    docs = rng.integers(0, cfg.vocab_size, (n_docs, doc_len)).astype(np.int32)

    print(f"embedding {n_docs} docs with {cfg.name} ...")
    embed_fn = embed
    try:
        doc_embeds = embed_fn(params, cfg, jnp.asarray(docs))
        lm_ok = True
    except Exception as e:  # pre-existing model-stack jax API drift
        print(f"  backbone unavailable ({type(e).__name__}); "
              "falling back to token-embedding pooling")
        embed_fn = embed_fallback
        doc_embeds = embed_fn(params, cfg, jnp.asarray(docs))
        lm_ok = False
    dim = doc_embeds.shape[1]

    idx_cfg = HNSWConfig(cap=2 * n_docs, dim=dim, M=12, M_up=6,
                         num_upper=2, ef_search=32, ef_construction=32,
                         k=4, rho=0.8, use_filter=True)
    index = LSMVecIndex.build(idx_cfg, doc_embeds)
    engine = ServeEngine(index, ServeConfig(
        query_batch=n_requests, insert_batch=8, delete_batch=8,
        query_window=0.002, insert_window=0.005, delete_window=0.005,
        maintenance=MaintenancePolicy(tombstone_ratio=0.2, check_every=4)))
    print(f"index built; resident {index.memory_bytes()/1e6:.2f} MB")

    # live update: new documents arrive while serving — submitted
    # individually, coalesced by the engine into one padded batch
    new_docs = rng.integers(0, cfg.vocab_size, (8, doc_len)).astype(np.int32)
    ins = [engine.submit_insert(e)
           for e in embed_fn(params, cfg, jnp.asarray(new_docs))]
    engine.drain()
    print(f"inserted docs {[t.result() for t in ins][:4]} ... "
          f"(1 micro-batch, {engine.metrics.snapshot()['insert']['batches']}"
          " dispatched)")
    docs = np.concatenate([docs, new_docs])

    # serve a burst of requests: one submit per client, one micro-batch
    # on the device
    queries = rng.integers(0, cfg.vocab_size,
                           (n_requests, doc_len)).astype(np.int32)
    t0 = time.monotonic()
    q_embeds = embed_fn(params, cfg, jnp.asarray(queries))
    index.reset_stats()
    tickets = [engine.submit_query(q) for q in q_embeds]
    engine.drain()
    doc_ids = np.stack([t.result().ids for t in tickets])
    retrieve_cost = index.io_cost(DISK) * 1e3 / n_requests

    # prepend retrieved doc, prefill, greedy-decode continuation
    ctx = np.concatenate([docs[doc_ids[:, 0]], queries], axis=1)
    if lm_ok:
        last, state = T.prefill(params, cfg, jnp.asarray(ctx),
                                max_len=ctx.shape[1] + gen_len)
        tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        outs = [np.asarray(tok)[:, 0]]
        for _ in range(gen_len - 1):
            logits, state = T.decode_step(params, cfg, state, tok)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            outs.append(np.asarray(tok)[:, 0])
        gen = np.stack(outs, axis=1)
    else:
        gen = np.zeros((n_requests, gen_len), np.int32)   # retrieval-only
    wall = time.monotonic() - t0
    m = engine.metrics.snapshot()
    print(f"served {n_requests} requests in {wall:.2f}s "
          f"({wall/n_requests*1e3:.0f} ms/req wall on 1 CPU core)")
    print(f"engine: {m['query']['batches']} query micro-batches, "
          f"mean occupancy {m['query']['mean_batch']}, "
          f"p50 {m['query']['p50_ms']:.1f} ms, "
          f"{m['snapshot_resolves']} snapshot resolves")
    print(f"modeled retrieval I/O: {retrieve_cost:.2f} ms/req "
          f"({int(index.io_stats.n_vec)} vector fetches, "
          f"{int(index.io_stats.n_filtered)} skipped by sampling)")
    for i in range(min(3, n_requests)):
        print(f"req {i}: retrieved doc {int(doc_ids[i, 0])}, "
              f"generated {gen[i][:8].tolist()} ...")


if __name__ == "__main__":
    main()
