"""End-to-end serving driver: retrieval-augmented generation over LSM-VEC.

The paper's motivating deployment (§1): a vector database serving ANN
queries for RAG.  This driver wires the full path with batched requests:

  1. a small LM (the qwen3-family smoke config) embeds documents by
     mean-pooling its final hidden states;
  2. documents live in an LSM-VEC index (insert/delete at any time);
  3. each request batch: embed queries -> sampled graph search (rho=0.8,
     Hoeffding filter on) -> retrieved doc tokens are prepended -> prefill
     + greedy decode continues the sequence.

    PYTHONPATH=src python examples/serve_rag.py
"""

import sys

sys.path.insert(0, "src")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import DISK, HNSWConfig, LSMVecIndex
from repro.models import transformer as T


def embed(params, cfg, tokens):
    """Mean-pooled final hidden state as the document/query embedding."""
    x = params["embed"][tokens].astype(cfg.act_dtype)
    positions = jnp.arange(x.shape[1])[None, :]
    h, _ = T._backbone(params, cfg, x, positions, remat=False)
    return np.asarray(jnp.mean(h, axis=1), np.float32)


def main(n_docs=512, doc_len=24, n_requests=8, gen_len=12):
    cfg = configs.get_config("qwen3-8b", "smoke")
    model = T.Model(cfg)
    params = model.init(jax.random.key(0))

    rng = np.random.default_rng(0)
    docs = rng.integers(0, cfg.vocab_size, (n_docs, doc_len)).astype(np.int32)

    print(f"embedding {n_docs} docs with {cfg.name} ...")
    doc_embeds = embed(params, cfg, jnp.asarray(docs))
    dim = doc_embeds.shape[1]

    idx_cfg = HNSWConfig(cap=2 * n_docs, dim=dim, M=12, M_up=6,
                         num_upper=2, ef_search=32, ef_construction=32,
                         k=4, rho=0.8, use_filter=True)
    index = LSMVecIndex.build(idx_cfg, doc_embeds)
    print(f"index built; resident {index.memory_bytes()/1e6:.2f} MB")

    # live update: new documents arrive while serving
    new_docs = rng.integers(0, cfg.vocab_size, (8, doc_len)).astype(np.int32)
    index.insert_batch(embed(params, cfg, jnp.asarray(new_docs)))
    docs = np.concatenate([docs, new_docs])

    # batched requests
    queries = rng.integers(0, cfg.vocab_size,
                           (n_requests, doc_len)).astype(np.int32)
    t0 = time.monotonic()
    q_embeds = embed(params, cfg, jnp.asarray(queries))
    index.reset_stats()
    doc_ids, _ = index.search(q_embeds, k=1)
    retrieve_cost = index.io_cost(DISK) * 1e3 / n_requests

    # prepend retrieved doc, prefill, greedy-decode continuation
    ctx = np.concatenate([docs[doc_ids[:, 0]], queries], axis=1)
    last, state = T.prefill(params, cfg, jnp.asarray(ctx),
                            max_len=ctx.shape[1] + gen_len)
    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
    outs = [np.asarray(tok)[:, 0]]
    for _ in range(gen_len - 1):
        logits, state = T.decode_step(params, cfg, state, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        outs.append(np.asarray(tok)[:, 0])
    wall = time.monotonic() - t0

    gen = np.stack(outs, axis=1)
    print(f"served {n_requests} requests in {wall:.2f}s "
          f"({wall/n_requests*1e3:.0f} ms/req wall on 1 CPU core)")
    print(f"modeled retrieval I/O: {retrieve_cost:.2f} ms/req "
          f"({int(index.stats.n_vec)} vector fetches, "
          f"{int(index.stats.n_filtered)} skipped by sampling)")
    for i in range(min(3, n_requests)):
        print(f"req {i}: retrieved doc {int(doc_ids[i, 0])}, "
              f"generated {gen[i][:8].tolist()} ...")


if __name__ == "__main__":
    main()
