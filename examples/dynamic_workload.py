"""Dynamic-workload demo on the online serving engine: the paper's
balanced insert-delete churn (Fig. 5 protocol) interleaved with queries,
driven through `repro.serve` micro-batching (DESIGN.md §8), with
non-blocking (double-buffered) consolidation overlapping the query
stream (DESIGN.md §13).

Each batch round submits individual insert/delete/query requests like
independent clients; the engine coalesces them into fixed-shape padded
micro-batches, serves queries from the cached LSM snapshot, and runs
threshold-triggered compaction in the background.  Per round it prints
recall, modeled update/search latency, memory, and engine stats.

The engine programs against the `VectorBackend` protocol (DESIGN.md
§10), so the same script serves a hash-partitioned multi-shard backend
unchanged:

    PYTHONPATH=src python examples/dynamic_workload.py
    PYTHONPATH=src python examples/dynamic_workload.py --shards 4
"""

import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import DISK, HNSWConfig, LSMVecIndex
from repro.core.distributed import ShardedBackend
from repro.core.index import brute_force_knn, recall_at_k
from repro.data.synth import make_clustered_vectors
from repro.serve import MaintenancePolicy, ServeConfig, ServeEngine


def main(n_base=1024, dim=48, n_batches=5, n_shards=1):
    base = make_clustered_vectors(n_base, dim=dim, seed=0)
    fresh = make_clustered_vectors(512, dim=dim, seed=1)
    queries = make_clustered_vectors(32, dim=dim, seed=7)
    cfg = HNSWConfig(cap=4096 // max(n_shards, 1) + 512, dim=dim, M=12,
                     M_up=6, num_upper=2, ef_search=48, ef_construction=48,
                     k=10, rho=0.8, use_filter=True)
    if n_shards > 1:
        backend = ShardedBackend(cfg, n_shards).build(base)
    else:
        backend = LSMVecIndex.build(cfg, base)
    engine = ServeEngine(backend, ServeConfig(
        query_batch=32, insert_batch=16, delete_batch=16,
        maintenance=MaintenancePolicy(tombstone_ratio=0.15, check_every=2,
                                      # overlapped consolidation is the
                                      # default; False = stop-the-world
                                      overlap=True)))

    allv = [base.copy()]
    live = np.ones(n_base, bool)
    rng = np.random.default_rng(3)
    cursor = 0
    batch_n = max(8, n_base // 100)

    print(f"serving over {type(backend).__name__}"
          + (f" ({n_shards} shards)" if n_shards > 1 else ""))
    print("batch,recall,update_ms,search_ms,memory_mb,n_live,maintenance")
    for b in range(n_batches):
        backend.reset_stats()
        for _ in range(batch_n // 2):          # 50% inserts
            x = fresh[cursor]
            cursor += 1
            engine.submit_insert(x)
            allv = [np.concatenate(allv + [x[None]])]
            live = np.append(live, True)
        victims = rng.choice(np.flatnonzero(live), batch_n // 2,
                             replace=False)
        for v in victims:                      # 50% deletes
            engine.submit_delete(int(v))
            live[v] = False
        engine.drain()
        upd_ms = backend.io_cost(DISK) * 1e3 / batch_n

        backend.reset_stats()
        tickets = [engine.submit_query(q) for q in queries]
        engine.drain()
        ids = np.stack([t.result().ids for t in tickets])
        srch_ms = backend.io_cost(DISK) * 1e3 / len(queries)
        truth = brute_force_knn(jnp.asarray(allv[0]), jnp.asarray(queries),
                                10, live=jnp.asarray(live))
        rec = recall_at_k(ids, truth)
        maint = dict(engine.metrics.maintenance_runs)
        print(f"{b},{rec:.3f},{upd_ms:.2f},{srch_ms:.2f},"
              f"{backend.memory_bytes()/1e6:.2f},{int(live.sum())},"
              f"{maint}")
    # settle any still-in-flight overlapped repair before final stats
    engine.maintenance.barrier()

    m = engine.metrics.snapshot()
    st = backend.stats()
    windows = [round(m[o]["window_ms"], 3)
               for o in ("query", "insert", "delete")]
    print(f"\nengine: {m['query']['batches']} query / "
          f"{m['insert']['batches']} insert / {m['delete']['batches']} "
          f"delete micro-batches, {m['snapshot_resolves']} snapshot "
          f"resolves, adaptive windows {windows} ms")
    print(f"backend: {st.size} live, {st.n_tombstones} tombstones, "
          f"{len(st.shards)} shard(s) "
          f"{[(s.size, s.n_tombstones) for s in st.shards]}")


if __name__ == "__main__":
    shards = 1
    if "--shards" in sys.argv:
        shards = int(sys.argv[sys.argv.index("--shards") + 1])
    main(n_shards=shards)
